"""Malleable-jobs figure (DESIGN.md §17): wait/utilization vs the rigid
frontier.

The scenario family the malleable subsystem opens: the same congested
synthetic workload scheduled rigid (every job at its requested width) and
malleable — moldable width choice at dispatch, then elastic grow/shrink
under queue pressure — swept over an Amdahl serial-fraction grid under two
queue policies.  Curve parameters and policies are trace *data*, so each
mode's whole param × policy grid compiles to ONE executable; only the
width range and mode are static.

The smoke pass validates EVERY grid point (and both rigid baselines)
bit-exactly against the host reference simulator, including the chosen
widths, dilated durations, resize counts and node-second ledgers; the full
run oracle-checks a sampled elastic point.

Emits ``fig_malleable/<mode>/<policy>/f=<param>`` rows with
``wait_vs_rigid:utilization:parallel_eff`` in the derived column; the
table lands in ``results/fig_malleable.csv`` and a machine-readable
``results/fig_malleable.json`` — including the frontier (per policy ×
mode, the serial fraction with the best wait reduction over rigid) —
uploaded by CI next to ``BENCH_engine.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from benchmarks import common
from repro.api import (
    MalleableModel, Scenario, SyntheticTrace, run, run_ref, sweep,
)

# Amdahl serial fractions: nearly-perfect scaling (0.05) to serial-bound
# (0.5) — the width choice collapses toward the reference width as the
# curve flattens, so the frontier sits strictly inside the grid
PARAMS = (0.05, 0.2, 0.5)
POLICIES = ("fcfs", "backfill")
MAL_COLS = ("mal_width", "mal_nref", "mal_nresize", "mal_node_s", "mal_dur")
SUMMARY_KEYS = ("avg_wait", "p95_wait", "utilization", "makespan",
                "mean_width", "mean_dilation", "total_resizes",
                "parallel_efficiency")


def _base(n_jobs: int) -> Scenario:
    return Scenario(trace=SyntheticTrace(n_jobs=n_jobs, seed=5, congest=4),
                    total_nodes=64, policy="backfill")


def _models(max_ticks: int):
    mold = MalleableModel(curve="amdahl", param=PARAMS[0], min_width=1,
                          max_width=16, mode="moldable")
    elast = dataclasses.replace(mold, mode="elastic", interval=64,
                                max_ticks=max_ticks, shrink_threshold=24,
                                grow_threshold=4, step=4)
    return (("moldable", mold), ("elastic", elast))


def _check(res, point) -> None:
    ref = run_ref(res.scenario)
    assert res.matches(ref), point
    n = int(ref["valid"].sum())
    for col in MAL_COLS:
        assert np.array_equal(res[col][:n], ref[col]), (point, col)


def _run(n_jobs: int, max_ticks: int, *, validate: bool,
         outdir: str = "results", smoke: bool = False):
    os.makedirs(outdir, exist_ok=True)
    report = {"schema": 1, "smoke": smoke, "generated_unix": time.time(),
              "rigid": {}, "points": [], "frontier": {}}
    base = _base(n_jobs)

    # rigid baselines: the frontier every malleable point is scored against
    for pol in POLICIES:
        res = run(base.with_(policy=pol))
        if validate:
            assert res.matches(run_ref(res.scenario)), pol
        s = res.summary()
        report["rigid"][pol] = {k: s[k] for k in
                                ("avg_wait", "p95_wait", "utilization",
                                 "makespan")}

    rows = []
    for mode_name, model in _models(max_ticks):
        mal_scn = base.with_(malleable=model)
        axes = {"malleable.param": PARAMS, "policy": POLICIES}
        grid_holder = []

        def run_grid():
            grid_holder[:] = [sweep(mal_scn, axes=axes)]
            return [r.raw.n_events for r in grid_holder[0].results]

        secs = common.time_call(run_grid, warmup=1, iters=1)
        grid = grid_holder[0]
        # the curve family and both thresholds are vmap data: ONE compile
        assert grid.n_compiles == 1, grid.n_compiles

        for point, res in grid:
            if validate:
                _check(res, point)
            s = res.summary()
            pol, param = point["policy"], point["malleable.param"]
            vs_rigid = s["avg_wait"] / max(report["rigid"][pol]["avg_wait"],
                                           1e-9)
            common.emit(
                f"fig_malleable/{mode_name}/{pol}/f={param}",
                secs / len(grid),
                f"{vs_rigid:.4f}:{s['utilization']:.4f}"
                f":{s['parallel_efficiency']:.4f}")
            rows.append((mode_name, pol, param,
                         *(s[k] for k in SUMMARY_KEYS), vs_rigid))
            report["points"].append({
                "mode": mode_name, "policy": pol, "param": param,
                "wait_vs_rigid": vs_rigid,
                **{k: s[k] for k in SUMMARY_KEYS}})

        if not validate and mode_name == "elastic":
            # the full run still oracle-checks one sampled elastic point
            probe = grid.get(**{"malleable.param": PARAMS[1],
                                "policy": "backfill"})
            _check(probe, "sampled elastic probe")
            print("# sampled oracle check ok", flush=True)

    # frontier: per policy x mode, the param with the best wait reduction
    for pol in POLICIES:
        for mode_name, _ in _models(max_ticks):
            cell = [p for p in report["points"]
                    if p["policy"] == pol and p["mode"] == mode_name]
            best = min(cell, key=lambda p: p["wait_vs_rigid"])
            report["frontier"][f"{pol}/{mode_name}"] = {
                "param": best["param"],
                "wait_vs_rigid": best["wait_vs_rigid"],
                "utilization": best["utilization"],
                "parallel_efficiency": best["parallel_efficiency"]}

    common.series_to_csv(
        os.path.join(outdir, "fig_malleable.csv"),
        ["mode", "policy", "param", *SUMMARY_KEYS, "wait_vs_rigid"],
        rows)
    report["finished_unix"] = time.time()
    path = os.path.join(outdir, "fig_malleable.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return report


def main():
    _run(400, 256, validate=False)


def smoke():
    """CI dry pass: small trace, every grid point and both rigid baselines
    validated vs refsim (schedules, widths, ledgers)."""
    return _run(80, 32, validate=True, smoke=True)


if __name__ == "__main__":
    import sys

    smoke() if "--smoke" in sys.argv else main()
