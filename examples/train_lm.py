"""End-to-end training driver: a ~100M-parameter llama-family model trained
for a few hundred steps with checkpointing, fault injection, straggler
monitoring, and gradient compression — the full production loop at CPU scale.

    PYTHONPATH=src python examples/train_lm.py                 # ~25M, 100 steps
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --inject-failure-at 40
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig, register  # noqa: E402
from repro.data.pipeline import SyntheticTokens  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: E402

PRESETS = {
    # ~25M params: fast on 1 CPU core (~0.2 s/step)
    "25m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                d_ff=1024, vocab=8192, head_dim=64),
    # ~100M params: the assignment's end-to-end scale (~2 s/step on CPU)
    "100m": dict(n_layers=10, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab=16384, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="25m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: /tmp/repro_train_lm_<preset>")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if args.ckpt_dir is None:
        args.ckpt_dir = f"/tmp/repro_train_lm_{args.preset}"
    cfg = ModelConfig(
        name=f"example-{args.preset}", family="dense",
        rope_theta=10_000.0, dtype="float32", remat=False,
        block_q=128, block_k=128, **PRESETS[args.preset],
    )
    register(cfg)
    from repro.models.api import get_model
    print(f"model: {get_model(cfg).n_params() / 1e6:.1f}M params")

    ds = SyntheticTokens(cfg.vocab, batch=args.batch, seq=args.seq, seed=0)
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 4, 10), log_every=10,
                      inject_failure_at=args.inject_failure_at,
                      compress_grads=args.compress_grads),
        ds,
    )
    out = trainer.run()
    if out["final_loss"] is None:
        print("\nno steps ran (checkpoint already at/past --steps; "
              "raise --steps or clear --ckpt-dir)")
        return
    print(f"\nfinal loss {out['final_loss']:.4f} after {args.steps} steps "
          f"({out['restarts']} restarts)")
    print(f"step time: mean {out['straggler']['mean_s']*1e3:.0f} ms, "
          f"p95 {out['straggler']['p95_s']*1e3:.0f} ms")


if __name__ == "__main__":
    main()
