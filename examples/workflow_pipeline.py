"""Workflow management end-to-end (paper §3): build a Montage-style DAG,
serialize it to the paper's JSON format, simulate it under three policies,
and validate against the reference engine.

    PYTHONPATH=src python examples/workflow_pipeline.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core.workflow import (  # noqa: E402
    WF_POLICY_IDS, critical_path_length, make_taskset, simulate_workflow,
    workflow_result_np,
)
from repro.refsim.workflow import simulate_workflow_reference  # noqa: E402
from repro.traces import workflows as W  # noqa: E402

POOLS = np.array([32, 65536])  # 32 cpus, 64 GB


def run(wf, policy, priority=None):
    ts = make_taskset(wf["exec_time"], wf["resources"], wf["dep_pairs"],
                      priority=priority)
    state = simulate_workflow(ts, POOLS, WF_POLICY_IDS[policy])
    return workflow_result_np(ts, state)


def main():
    wf = W.galactic_like(tiles=6, width=14, seed=3)
    n = len(wf["exec_time"])
    print(f"Galactic-like workflow: {n} tasks, {len(wf['dep_pairs'])} edges")

    js = W.to_json(wf, POOLS)
    print(f"paper-format JSON: {len(js)} bytes "
          f"(round-trips: {W.from_json(js)[0]['exec_time'].shape == (n,)})\n")

    print(f"{'policy':10s} {'makespan':>9s} {'mean task wait':>15s} "
          f"{'matches ref':>11s}")
    for policy in ("fcfs", "fcfs_fit", "cpath"):
        prio = (critical_path_length(wf["exec_time"], wf["dep_pairs"])
                if policy == "cpath" else None)
        ours = run(wf, policy, prio)
        ref = simulate_workflow_reference(
            wf["exec_time"], wf["resources"], wf["dep_pairs"], POOLS, policy,
            priority=prio)
        match = bool((ours["start"][:n] == ref["start"]).all())
        print(f"{policy:10s} {ours['makespan']:9d} "
              f"{ours['wait'][:n].mean():15.1f} {str(match):>11s}")

    # SIPHT wait-time validation (paper Fig. 7)
    sip = W.sipht_like(30, seed=4)
    ours = run(sip, "fcfs")
    ref = simulate_workflow_reference(
        sip["exec_time"], sip["resources"], sip["dep_pairs"], POOLS, "fcfs")
    m = len(sip["exec_time"])
    print(f"\nSIPHT: wait-time exact match vs reference: "
          f"{int((ours['wait'][:m] == ref['wait']).sum())}/{m}")

    # the same DAG as first-class *cluster* jobs (DESIGN.md §13): concrete
    # node placement + EASY backfill interacting with the dependency
    # structure, validated bit-exactly against the cluster reference sim
    from repro.api import Scenario, Topology, WorkflowTrace
    from repro.api import run as cluster_run, run_ref as cluster_run_ref

    scn = Scenario(trace=WorkflowTrace(kind="sipht", seed=4,
                                       params=(("width", 30),)),
                   topology=Topology.mesh2d(4, 8), policy="backfill",
                   alloc="contiguous")
    res = cluster_run(scn)
    out = res.to_np()
    v = out["valid"]
    print(f"on-cluster (mesh2d 4x8, backfill+contiguous): makespan "
          f"{out['makespan']}, mean ready-wait {out['wait'][v].mean():.1f}, "
          f"matches ref: {res.matches(cluster_run_ref(scn), node_maps=True)}")


if __name__ == "__main__":
    main()
