"""Asking what-if questions: the capacity-planning query service.

A :class:`repro.service.CapacityPlanner` loads a fleet of named queue
scenarios once and answers versioned, JSON-round-trippable queries —
"where should this job run", "what happens to p99 wait if we add 64
nodes", "which MTBF budget meets a goodput target" — by lowering each
onto the existing ``sweep()`` API (DESIGN.md §20).  Because scenario
buckets reuse the persistent compiled executables, the first query of
each shape pays the XLA compile and every later one runs in
milliseconds; the per-answer ``cache`` counters make that visible.

The same planner serves over stdlib HTTP:
``python -m repro.service --demo`` (see tests/test_service.py's smoke).

    PYTHONPATH=src python examples/whatif_queries.py
"""

from repro.service import (
    CapacityPlanner, JobRequest, Objective, ScenarioDelta, WhatIfQuery,
    demo_fleet,
)

planner = CapacityPlanner(demo_fleet())

status = planner.fleet_status()
print("fleet:")
for name, q in status["queues"].items():
    s = q["summary"]
    print(f"  {name:6s} {q['total_nodes']:4d} nodes  policy={q['policy']:9s}"
          f" util={s['utilization']:.2f}  p99_wait={s['p99_wait']:.0f}s")

# -- where should this job run? ---------------------------------------------
job = JobRequest(submit=0, runtime=1800, nodes=24)
ans = planner.answer(WhatIfQuery(kind="placement", job=job))
print(f"\nplace a {job.nodes}-node, {job.runtime}s job "
      f"-> {ans['recommended']!r}")
for rec in ans["recommendations"]:
    print(f"  #{rec['rank']} {rec['label']:6s} candidate waits "
          f"{rec['value']:.0f}s")

# every query round-trips through its canonical JSON form byte-for-byte —
# what goes over the wire is exactly what the planner answers
wire = ans and WhatIfQuery(kind="placement", job=job).to_json()
assert WhatIfQuery.from_json(wire).to_json() == wire

# -- what happens to p99 wait if we add nodes? ------------------------------
ans = planner.answer(WhatIfQuery(
    kind="capacity", queue="batch",
    deltas=(ScenarioDelta(),
            ScenarioDelta(add_nodes=32),
            ScenarioDelta(add_nodes=64),
            ScenarioDelta(add_nodes=64, policy="backfill"))))
print("\ngrow the batch queue (objective: min p99_wait):")
for rec in ans["recommendations"]:
    print(f"  #{rec['rank']} {rec['label']:24s} p99_wait={rec['value']:8.0f}s"
          f"  ({rec['delta']:+.0f}s vs as-is)")
print(f"  cache: {ans['cache']['compiles']} compiles, "
      f"{ans['cache']['hits']} hits")

# -- which MTBF budget meets a goodput target? ------------------------------
ans = planner.answer(WhatIfQuery(
    kind="reliability", queue="flaky",
    mtbf_grid=(500e3, 1000e3, 2000e3, 4000e3),
    objective=Objective(metric="goodput", goal="max", target=0.85)))
print("\nMTBF budget for goodput >= 0.85 on the flaky queue "
      f"-> {ans['recommended']!r}")
for rec in ans["recommendations"]:
    mark = "meets" if rec["meets_target"] else "misses"
    print(f"  #{rec['rank']} {rec['label']:14s} goodput={rec['value']:.3f}"
          f"  ({mark} target)")

# a repeated query (new candidate values, same shapes) is pure cache hits
ans = planner.answer(WhatIfQuery(
    kind="placement", job=JobRequest(submit=300, runtime=60, nodes=4)))
assert ans["cache"]["compiles"] == 0, ans["cache"]
print(f"\nrepeat placement query: {ans['cache']['hits']} cache hits, "
      "0 compiles")
