"""Serving an open request stream: what rate can this cluster absorb?

A 16-node cluster serves Poisson arrivals from two request classes —
latency-sensitive interactive jobs and wide batch jobs — each with its own
wait-time SLO.  The question every capacity planner asks: up to what
arrival rate does the cluster keep >= 95% of requests inside their SLO,
and does queue-pressure autoscaling (parking idle nodes, waking them when
the queue builds) change that frontier?

The whole 12-point rate x autoscale grid — arrival streams, SLO deadlines
and scaler thresholds included — batches into ONE compiled executable
(DESIGN.md §16), and any point validates bit-exactly against the host
reference simulator.

    PYTHONPATH=src python examples/serving_slo.py
"""

import dataclasses

from repro.api import (
    AutoscalePolicy, Scenario, ServiceClass, ServiceTrace, run_ref, sweep,
)

TARGET = 0.95

base = Scenario(
    trace=ServiceTrace(
        horizon=20_000,            # observation window (s)
        rate=0.05,                 # requests/s (swept below)
        seed=42,
        max_jobs=2048,             # padded request capacity (static axis)
        classes=(
            ServiceClass("interactive", nodes=1, mean_runtime=40,
                         slo_wait=120),
            ServiceClass("batch", nodes=4, mean_runtime=300,
                         dist="exponential", slo_wait=900, weight=0.25),
        ),
        autoscale=AutoscalePolicy(
            up_threshold=1,        # queued node-demand that wakes nodes
            down_threshold=0,      # park free nodes only on an idle queue
            min_nodes=4, max_nodes=16, step=4,
            interval=25,           # scaler decision period (s)
            max_ticks=1024,        # padded tick capacity (static axis)
        ),
    ),
    total_nodes=16,
    policy="fcfs",
)

# one executable for the 12-point grid: rate and every scaler threshold are
# trace *data*; disabling the scaler keeps the padded tick shape, so both
# columns share the compile too
# E[nodes x runtime] ~= 330 node-s/request -> 16 nodes saturate near
# 0.048 req/s; the grid spans under- to over-subscribed
RATES = (0.010, 0.018, 0.026, 0.034, 0.042, 0.050)
grid = sweep(base, axes={
    "trace.rate": RATES,
    "trace.autoscale": (base.trace.autoscale,
                        dataclasses.replace(base.trace.autoscale,
                                            enabled=False)),
})
assert grid.n_compiles == 1, grid.n_compiles
print(f"{len(grid)} grid points in {grid.n_compiles} compiled executable\n")

print(f"{'rate':>6} {'scaler':>7} {'attain':>7} {'p50w':>6} {'p99w':>7} "
      f"{'goodput':>8} {'requests':>9}")
frontier = {}
for point, res in grid:
    s = res.summary()
    scaled = point["trace.autoscale"].enabled
    tag = "auto" if scaled else "fixed"
    print(f"{point['trace.rate']:>6.3f} {tag:>7} {s['slo_attainment']:>7.3f} "
          f"{s['p50_wait']:>6.0f} {s['p99_wait']:>7.0f} "
          f"{s['slo_goodput']:>8.4f} {s['n_requests']:>9.0f}")
    if s["slo_attainment"] >= TARGET:
        frontier[tag] = max(frontier.get(tag, 0.0), point["trace.rate"])

for tag in ("fixed", "auto"):
    r = frontier.get(tag)
    answer = f"{r:.3f} req/s" if r else f"none of {RATES} met the target"
    print(f"\n{tag:>5}: highest rate with >= {TARGET:.0%} SLO attainment: "
          f"{answer}")

# every point is bit-exactly reproducible on the host reference simulator
check = grid.get(**{"trace.rate": 0.042,
                    "trace.autoscale": base.trace.autoscale})
assert check.matches(run_ref(check.scenario))
print("\nengine vs reference simulator: bit-exact at the checked point")
