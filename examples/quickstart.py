"""Quickstart: the paper's core loop through the Scenario API.

One declarative spec drives both engines: ``run`` (JAX) and ``run_ref``
(host reference simulator) take the SAME ``Scenario``, so validation is a
one-liner.  Simulates an HPC cluster under all five scheduling policies on
a synthetic DAS-2-like trace and prints the paper-Fig-4(b)-style table.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.api import Scenario, SyntheticTrace, run, run_ref  # noqa: E402
from repro.core import metrics  # noqa: E402

# congest=2 halves inter-arrival gaps so the policies actually diverge
BASE = Scenario(
    trace=SyntheticTrace(n_jobs=1500, seed=0, kind="das2", congest=2),
    total_nodes=400,
)


def main():
    print(f"{'policy':10s} {'avg wait':>9s} {'p95 wait':>9s} {'util':>6s} "
          f"{'makespan':>9s} {'matches ref':>11s}")
    for policy in ("fcfs", "bestfit", "backfill", "sjf", "ljf"):
        scn = BASE.with_(policy=policy)
        res = run(scn)
        exact = res.matches(run_ref(scn))
        s = res.summary()
        print(f"{policy:10s} {s['avg_wait']:9.0f} {s['p95_wait']:9.0f} "
              f"{s['utilization']:6.3f} {s['makespan']:9.0f} {str(exact):>11s}")

    # node-occupancy series (paper Fig. 3a)
    out = run(BASE.with_(policy="backfill")).to_np()
    total = BASE.total_nodes
    t, occ = metrics.occupancy_series(out)
    grid = np.linspace(0, out["makespan"], 12)
    samp = metrics.sample_series(t, occ, grid)
    print("\noccupancy over time (backfill):")
    for g, v in zip(grid, samp):
        print(f"  t={g:9.0f}s  {'#' * int(40 * v / total):40s} {v:.0f}")


if __name__ == "__main__":
    main()
