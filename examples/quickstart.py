"""Quickstart: the paper's core loop in ~40 lines.

Simulates an HPC cluster under all five scheduling policies on a synthetic
DAS-2-like trace, validates against the reference simulator, and prints the
paper-Fig-4(b)-style comparison table.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import metrics  # noqa: E402
from repro.core.engine import simulate_np  # noqa: E402
from repro.refsim import simulate_reference  # noqa: E402
from repro.traces import das2_like  # noqa: E402

TOTAL_NODES = 400


def main():
    trace = das2_like(1500, seed=0)
    trace["submit"] //= 2  # congest the cluster so policies differ

    print(f"{'policy':10s} {'avg wait':>9s} {'p95 wait':>9s} {'util':>6s} "
          f"{'makespan':>9s} {'matches ref':>11s}")
    for policy in ("fcfs", "bestfit", "backfill", "sjf", "ljf"):
        ours = simulate_np(trace, policy, total_nodes=TOTAL_NODES)
        ref = simulate_reference(trace, policy, total_nodes=TOTAL_NODES)
        n = len(ref["start"])
        exact = bool((ours["start"][:n] == ref["start"]).all())
        s = metrics.summary(ours, TOTAL_NODES)
        print(f"{policy:10s} {s['avg_wait']:9.0f} {s['p95_wait']:9.0f} "
              f"{s['utilization']:6.3f} {s['makespan']:9.0f} {str(exact):>11s}")

    # node-occupancy series (paper Fig. 3a)
    out = simulate_np(trace, "backfill", total_nodes=TOTAL_NODES)
    t, occ = metrics.occupancy_series(out)
    grid = np.linspace(0, out["makespan"], 12)
    samp = metrics.sample_series(t, occ, grid)
    print("\noccupancy over time (backfill):")
    for g, v in zip(grid, samp):
        print(f"  t={g:9.0f}s  {'#' * int(40 * v / TOTAL_NODES):40s} {v:.0f}")


if __name__ == "__main__":
    main()
