"""Allocator comparison through one ``sweep()``: one trace, one dragonfly
machine, 4 placement strategies × 2 contention settings — an 8-point grid
in a single compiled executable (DESIGN.md §12), each point validated
bit-exact (including node maps) against the reference simulator.

    PYTHONPATH=src python examples/alloc_compare.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.api import (  # noqa: E402
    Scenario, SyntheticTrace, Topology, run, run_ref, sweep,
)
from repro.core import metrics  # noqa: E402

GROUPS, PER_GROUP = 16, 8
TOTAL = GROUPS * PER_GROUP

BASE = Scenario(
    trace=SyntheticTrace(n_jobs=600, seed=11, kind="sdsc_sp2"),
    topology=Topology.dragonfly(GROUPS, PER_GROUP),
    policy="backfill",
)

STRATEGIES = ("simple", "contiguous", "spread", "topo")
CONTENTIONS = (None, (1, 5))   # off / +20% runtime per extra group spanned


def main():
    grid = sweep(BASE, axes={"contention": CONTENTIONS, "alloc": STRATEGIES})
    print(f"8-point alloc x contention grid in {grid.n_compiles} compile(s)")

    for con in CONTENTIONS:
        label = "contention off" if con is None else "contention +20%/group"
        print(f"\n{label}:  ({GROUPS} groups x {PER_GROUP} nodes, backfill)")
        print(f"{'strategy':12s} {'makespan':>9s} {'avg wait':>9s} "
              f"{'job span':>9s} {'frag':>6s} {'matches ref':>11s}")
        for strat in STRATEGIES:
            res = grid.get(alloc=strat, contention=con)
            exact = res.matches(run_ref(res.scenario), node_maps=True)
            s = res.summary()
            print(f"{strat:12s} {s['makespan']:9.0f} {s['avg_wait']:9.0f} "
                  f"{s['mean_job_span']:9.2f} {s['mean_frag']:6.3f} "
                  f"{str(exact):>11s}")

    # fragmentation over time for the block allocator
    out = run(BASE.with_(alloc="contiguous")).to_np()
    t, lfb = metrics.largest_free_block_series(out)
    grid_t = np.linspace(0, out["makespan"], 10)
    samp = metrics.sample_series(t, lfb, grid_t)
    print("\nlargest free contiguous block over time (contiguous):")
    for g, v in zip(grid_t, samp):
        print(f"  t={g:9.0f}s  {'#' * int(40 * v / TOTAL):40s} {v:.0f}")


if __name__ == "__main__":
    main()
