"""Allocator comparison: one trace, one dragonfly machine, four placement
strategies — different node maps, different locality, and (with contention)
different makespans (DESIGN.md §11).

    PYTHONPATH=src python examples/alloc_compare.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import alloc  # noqa: E402
from repro.core import metrics  # noqa: E402
from repro.core.engine import simulate_np  # noqa: E402
from repro.refsim import simulate_reference  # noqa: E402
from repro.traces import sdsc_sp2_like  # noqa: E402

GROUPS, PER_GROUP = 16, 8
TOTAL = GROUPS * PER_GROUP


def main():
    trace = sdsc_sp2_like(600, seed=11)
    machine = alloc.dragonfly(GROUPS, PER_GROUP)

    for con, label in ((None, "contention off"),
                       (alloc.Contention.make(1, 5), "contention +20%/group")):
        print(f"\n{label}:  ({GROUPS} groups x {PER_GROUP} nodes, backfill)")
        print(f"{'strategy':12s} {'makespan':>9s} {'avg wait':>9s} "
              f"{'job span':>9s} {'frag':>6s} {'matches ref':>11s}")
        for strat in ("simple", "contiguous", "spread", "topo"):
            out = simulate_np(trace, "backfill", total_nodes=TOTAL,
                              machine=machine, alloc=strat, contention=con)
            ref = simulate_reference(trace, "backfill", total_nodes=TOTAL,
                                     machine=machine, alloc=strat,
                                     contention=con)
            n = len(ref["start"])
            exact = bool(
                (out["start"][:n] == ref["start"]).all()
                and (out["alloc_sum"][:n] == ref["alloc_sum"]).all())
            s = metrics.summary(out, TOTAL)
            a = metrics.alloc_summary(out)
            print(f"{strat:12s} {s['makespan']:9.0f} {s['avg_wait']:9.0f} "
                  f"{a['mean_job_span']:9.2f} {a['mean_frag']:6.3f} "
                  f"{str(exact):>11s}")

    # fragmentation over time for the block allocator
    out = simulate_np(trace, "backfill", total_nodes=TOTAL, machine=machine,
                      alloc="contiguous")
    t, lfb = metrics.largest_free_block_series(out)
    grid = np.linspace(0, out["makespan"], 10)
    samp = metrics.sample_series(t, lfb, grid)
    print("\nlargest free contiguous block over time (contiguous):")
    for g, v in zip(grid, samp):
        print(f"  t={g:9.0f}s  {'#' * int(40 * v / TOTAL):40s} {v:.0f}")


if __name__ == "__main__":
    main()
