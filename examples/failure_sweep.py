"""Simulating node failures: an MTBF x checkpoint-interval study.

Every node runs a seeded renewal process (up ~ Exp(MTBF), down ~
Exp(mean_repair)); a failure kills the job on the struck node, which
re-enters the queue charged for the work since its last checkpoint.  The
whole MTBF grid — failure streams included — batches into ONE compiled
executable, and any single point can be validated bit-exactly against the
host reference simulator (DESIGN.md §15).

    PYTHONPATH=src python examples/failure_sweep.py
"""

from repro.api import FailureModel, Scenario, SyntheticTrace, run_ref, sweep

base = Scenario(
    trace=SyntheticTrace(n_jobs=400, seed=0, kind="sdsc_sp2", congest=4),
    total_nodes=128,
    policy="backfill",
    failures=FailureModel(
        mtbf=50_000.0,             # per-node mean time between failures (s)
        mean_repair=600,           # mean outage duration (s)
        checkpoint_interval=3600,  # work since the last checkpoint is lost
        horizon=1 << 17,           # covers the ~1e5 s schedule
        max_failures=2048,         # padded stream capacity (the static axis)
        seed=7,
    ),
)
# capacity covers the harshest grid point below (~1.3k failures at
# mtbf=12.5k across 128 nodes) — no early-window truncation
_harshest = base.with_(**{"failures.mtbf": 12_500.0}).failures
assert not _harshest.materialize(128).truncated

# one executable for the whole grid: MTBF, checkpoint interval and the
# requeue/abort rule are all trace *data*, like policy or trace.seed
grid = sweep(base, axes={
    "failures.mtbf": (12_500.0, 25_000.0, 50_000.0, 100_000.0, 200_000.0,
                      400_000.0),
    "failures.checkpoint_interval": (0, 3600),
})
print(f"{len(grid)} grid points in {grid.n_compiles} compiled executable\n")

print(f"{'mtbf':>7} {'ckpt':>5} {'goodput':>8} {'avg_wait':>9} "
      f"{'restarts':>9} {'lost_node_s':>12}")
for point, res in grid:
    s = res.summary()
    print(f"{point['failures.mtbf']:>7.0f} "
          f"{point['failures.checkpoint_interval']:>5d} "
          f"{s['goodput']:>8.4f} {s['avg_wait']:>9.1f} "
          f"{s['total_restarts']:>9.0f} {s['lost_node_s']:>12.0f}")

# abort instead of requeue: jobs die, their dependents release (after-any)
aborting = base.with_(**{"failures.requeue": "abort",
                         "failures.mtbf": 12_500.0})
res = sweep(aborting, axes={}).results[0]
print(f"\nabort rule at mtbf=12.5k: {res.summary()['n_aborted']:.0f} jobs "
      f"aborted, goodput {res.summary()['goodput']:.4f}")

# every point is bit-exactly reproducible on the host reference simulator
check = grid.get(**{"failures.mtbf": 25_000.0,
                    "failures.checkpoint_interval": 3600})
assert check.matches(run_ref(check.scenario))
print("\nengine vs reference simulator: bit-exact at the checked point")
