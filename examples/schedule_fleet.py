"""Schedule a fleet of LM training/serving jobs on a simulated 512-chip
cluster — the paper's scheduler managing THIS framework's workloads.

Job runtimes come from the dry-run roofline table (results/dryrun/*.json):
each job is "train/serve arch X for N steps on P chips", its duration the
roofline-bound step time x steps.  Compares the five policies, evaluates
straggler-induced runtime inflation, and wires the straggler monitor's
evict decisions to the DES's malleable shrink action (DESIGN.md §17):
instead of evicting a straggling job (kill + requeue, full rework), the
scheduler sheds nodes from wide running jobs so the fleet absorbs the
inflation without losing work.

    PYTHONPATH=src python examples/schedule_fleet.py
"""

import glob
import json
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.api import ArrayTrace, MalleableModel, Scenario, run  # noqa: E402
from repro.runtime.straggler import StragglerMonitor  # noqa: E402

TOTAL_CHIPS = 512


def load_job_costs():
    """Roofline-bound step seconds per (arch, shape) from the dry-run."""
    costs = {}
    for p in glob.glob("results/dryrun/*__single.json"):
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        costs[(r["arch"], r["shape"])] = max(r["bound_step_s"], 1e-4)
    return costs


def synth_fleet(costs, n_jobs=300, seed=0):
    """A month of lab workload: training runs, prefill/serving batches."""
    rng = np.random.default_rng(seed)
    keys = sorted(costs)
    submit, runtime, nodes, estimate, prio, names = [], [], [], [], [], []
    t = 0
    for _ in range(n_jobs):
        t += int(rng.exponential(600))
        arch, shape = keys[rng.integers(len(keys))]
        step_s = costs[(arch, shape)]
        if shape == "train_4k":
            steps = int(rng.integers(200, 5000))   # a training run
            chips = 256
            pr = 2                                  # preemptible batch work
        elif shape == "prefill_32k":
            steps = int(rng.integers(50, 500))     # a batch-inference job
            chips = int(rng.choice([64, 128, 256]))
            pr = 1
        else:
            steps = int(rng.integers(1000, 20000))  # a decode serving session
            chips = int(rng.choice([32, 64, 128]))
            pr = 0                                  # latency-critical serving
        dur = max(int(step_s * steps), 1)
        submit.append(t)
        runtime.append(dur)
        nodes.append(chips)
        estimate.append(int(dur * rng.uniform(1.1, 2.0)))
        prio.append(pr)
        names.append(f"{arch}:{shape}")
    return {
        "submit": np.array(submit), "runtime": np.array(runtime),
        "nodes": np.array(nodes), "estimate": np.array(estimate),
        "priority": np.array(prio),
    }, names


def main():
    costs = load_job_costs()
    if not costs:
        print("no dry-run results found — run benchmarks.dryrun_sweep first;"
              " falling back to synthetic costs")
        costs = {("synthetic-7b", s): t for s, t in
                 [("train_4k", 2.0), ("prefill_32k", 1.0), ("decode_32k", 0.02)]}
    fleet, names = synth_fleet(costs)
    print(f"fleet: {len(names)} jobs over {fleet['submit'].max()/3600:.1f} h, "
          f"{len(costs)} distinct (arch x shape) job classes\n")

    base = Scenario(trace=ArrayTrace.from_dict(fleet),
                    total_nodes=TOTAL_CHIPS)

    print(f"{'policy':10s} {'avg wait (m)':>12s} {'p95 wait (m)':>12s} "
          f"{'util':>6s} {'makespan (h)':>12s} {'serve p95 (m)':>13s}")
    serve_rows = np.array([n.split(":")[1] not in ("train_4k", "prefill_32k")
                           for n in names])
    order = np.lexsort((np.arange(len(names)), fleet["submit"]))
    serve_sorted = serve_rows[order]
    for policy in ("fcfs", "bestfit", "backfill", "sjf", "ljf", "preempt"):
        res = run(base.with_(policy=policy))
        s = res.summary()
        sp95 = float(np.percentile(res["wait"][:len(names)][serve_sorted], 95))
        print(f"{policy:10s} {s['avg_wait']/60:12.1f} {s['p95_wait']/60:12.1f} "
              f"{s['utilization']:6.3f} {s['makespan']/3600:12.2f} "
              f"{sp95/60:13.1f}")
    print("  (preempt: decode=prio 0, prefill=1, training=2 — serving-job "
          "p95 wait is the target metric)")

    # straggler sensitivity: inflate 5% of job runtimes 1.7x (slow hosts)
    rng = np.random.default_rng(7)
    slow = rng.random(len(fleet["runtime"])) < 0.05
    inflated = dict(fleet)
    inflated["runtime"] = np.where(slow, (fleet["runtime"] * 1.7).astype(int),
                                   fleet["runtime"])
    a = run(base.with_(policy="backfill")).summary()
    b = run(base.with_(policy="backfill",
                       trace=ArrayTrace.from_dict(inflated))).summary()
    print(f"\nstraggler sensitivity (5% of jobs 1.7x slower, backfill):")
    print(f"  makespan {a['makespan']/3600:.2f} h -> {b['makespan']/3600:.2f} h; "
          f"avg wait {a['avg_wait']/60:.1f} m -> {b['avg_wait']/60:.1f} m")
    print("  => mitigation policy budget: evicting stragglers is worth up to "
          f"{(b['makespan']-a['makespan'])/3600:.2f} h of cluster time")

    # mitigation: the monitor's evict decisions map to a SHRINK action.
    # Feed it per-rank step times with one chronic straggler rank; each
    # "evict" historically meant kill + requeue (losing all work since the
    # last checkpoint).  With malleable jobs the same signal instead arms
    # the DES's elastic mode: under queue pressure the scheduler sheds
    # nodes from the widest running job (shrinking AROUND the slow host)
    # and regrows when the queue drains — no work is lost.
    mon = StragglerMonitor(n_ranks=8, patience=3)
    n_evict = 0
    for step in range(16):
        timings = [1.0 + 0.002 * step] * 8
        if step >= 4:
            timings[3] = 2.2            # chronic straggler on rank 3
        n_evict += sum(d.action == "evict" for d in mon.update(timings))
    print(f"\nstraggler monitor: {n_evict} evict decision(s) over 16 steps "
          "-> mapped to elastic shrink")
    if n_evict:
        mal = MalleableModel(curve="amdahl", param=0.02, min_width=32,
                             max_width=256, mode="elastic", interval=1800,
                             max_ticks=2048, shrink_threshold=256,
                             grow_threshold=32, step=32)
        c_res = run(base.with_(policy="backfill",
                               trace=ArrayTrace.from_dict(inflated),
                               malleable=mal))
        c = c_res.summary()
        print(f"  shrink-instead-of-evict (backfill, widths 32..256): "
              f"makespan {c['makespan']/3600:.2f} h, "
              f"avg wait {c['avg_wait']/60:.1f} m, "
              f"{c['total_resizes']:.0f} resizes, "
              f"parallel efficiency {c['parallel_efficiency']:.2f}")
        print(f"  vs rigid inflated run: makespan {b['makespan']/3600:.2f} h, "
              f"avg wait {b['avg_wait']/60:.1f} m")


if __name__ == "__main__":
    main()
